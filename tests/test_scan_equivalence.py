"""Equivalence of the sequential and chunk-parallel mixers (math contract).

wkv_chunked / mamba2 SSD chunks are pure reschedulings of the recurrences —
they must agree to float tolerance for arbitrary shapes (hypothesis-swept).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.rwkv6 import wkv_chunked, wkv_scan
import pytest

# 20 hypothesis examples x jit-compiled scans: the suite's slowest module.
# Deselected by `make test-fast`.
pytestmark = pytest.mark.slow


def _rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


@given(b=st.integers(1, 3), t=st.sampled_from([8, 16, 32, 64]),
       h=st.integers(1, 3), k=st.sampled_from([4, 8]),
       chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_wkv_chunked_equals_scan(b, t, h, k, chunk, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = _rand(keys[0], (b, t, h, k))
    kk = _rand(keys[1], (b, t, h, k))
    v = _rand(keys[2], (b, t, h, k))
    w = jax.random.uniform(keys[3], (b, t, h, k), jnp.float32, 0.05, 0.98)
    u = _rand(keys[4], (h, k))
    s0 = _rand(keys[5], (b, h, k, k))

    y1, s1 = wkv_scan(r, kk, v, w, u, s0)
    y2, s2 = wkv_chunked(r, kk, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_seq_matches_stepwise():
    """Full-sequence SSD == token-by-token recurrence, incl. conv state."""
    from repro.configs import get_config
    from repro.models.mamba2 import mamba2_seq, mamba2_step
    from repro.models.common import ParamBuilder
    from repro.models.mamba2 import init_mamba2

    cfg = get_config("zamba2-2.7b").smoke().replace(dtype="float32")
    b_ = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    init_mamba2(b_, cfg)
    p, _ = b_.build()

    bsz, t = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (bsz, t, cfg.d_model),
                          jnp.float32) * 0.3
    y_seq, s_seq, conv_seq = mamba2_seq(p, x, cfg, chunk=4)

    from repro.models.mamba2 import _dims
    d_in, h, pp, n = _dims(cfg)
    s = jnp.zeros((bsz, h, pp, n), jnp.float32)
    cs = jnp.zeros((bsz, cfg.ssm_conv_width - 1, d_in + 2 * n), jnp.float32)
    ys = []
    for i in range(t):
        y, s, cs = mamba2_step(p, x[:, i], cfg, s, cs)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_seq), np.asarray(s),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(conv_seq), np.asarray(cs),
                               rtol=3e-4, atol=3e-4)
