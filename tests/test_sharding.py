"""Sharding rule engine: divisibility fallbacks, axis reuse, full-zoo specs."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import get_model
from repro.sharding import (SERVE_RULES, TRAIN_RULES, resolve_spec,
                            tree_specs)


def _mesh(shape=(2, 2), names=("data", "model")):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, names)


MESH = _mesh()


class TestResolve:
    def test_basic_two_dim(self):
        # (embed, ff) with both divisible -> ('data', 'model')
        s = resolve_spec((64, 128), ("embed", "ff"), TRAIN_RULES, MESH)
        assert s == P("data", "model")

    def test_non_divisible_falls_back_to_replication(self):
        s = resolve_spec((63, 128), ("embed", "ff"), TRAIN_RULES, MESH)
        assert s == P(None, "model")

    def test_axis_reuse_forbidden(self):
        # experts -> data; embed also wants data but it's taken.
        s = resolve_spec((4, 64, 128), ("experts", "embed", "ff"),
                         TRAIN_RULES, MESH)
        assert s == P("data", None, "model")

    def test_multi_axis_batch(self):
        mesh = _mesh((2, 4, 2), ("pod", "data", "model"))
        s = resolve_spec((16, 128), ("batch", "seq"), TRAIN_RULES, mesh)
        assert s == P(("pod", "data"))

    def test_multi_axis_partial_fallback(self):
        # batch=2 on (pod=2, data=4): full product 8 fails, pick largest fit.
        mesh = _mesh((2, 4, 2), ("pod", "data", "model"))
        s = resolve_spec((2, 128), ("batch", "seq"), TRAIN_RULES, mesh)
        assert s == P("pod")

    def test_unknown_axis_replicates(self):
        s = resolve_spec((10, 10), ("mystery", "layers"), TRAIN_RULES, MESH)
        assert s == P()


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("rules", [TRAIN_RULES, SERVE_RULES],
                         ids=["train", "serve"])
def test_full_zoo_param_specs_resolve(arch, rules):
    """Every parameter of every arch gets a valid PartitionSpec on the
    production mesh shape (16, 16) — divisibility enforced by construction."""
    mesh = _mesh((16, 16), ("data", "model"))
    model = get_model(get_config(arch))
    shapes = model.param_shapes()
    specs_logical = model.param_specs()
    pspecs = tree_specs(shapes, specs_logical, rules, mesh)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    checked = 0
    for sds, spec in zip(jax.tree.leaves(shapes),
                         jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))):
        for dim, entry in zip(sds.shape, tuple(spec) + (None,) * 10):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, (arch, sds.shape, spec)
            checked += 1
    assert checked > 0, f"{arch}: nothing sharded at all"


def test_moe_expert_weights_sharded_over_data_and_ff():
    mesh = _mesh((16, 16), ("data", "model"))
    model = get_model(get_config("qwen3-moe-235b-a22b"))
    shapes = model.param_shapes()
    logical = model.param_specs()
    pspecs = tree_specs(shapes, logical, TRAIN_RULES, mesh)
    w1 = pspecs["layers"]["moe"]["w1"]          # (layers, E, d, ff)
    assert w1 == P(None, "data", None, "model")


def test_kv_cache_sequence_sharded_for_serve():
    mesh = _mesh((16, 16), ("data", "model"))
    cfg = get_config("qwen3-14b")
    model = get_model(cfg)
    from repro.configs import shape_for
    shape = shape_for("decode_32k")
    cache_shapes = model.cache_input_specs(shape)
    cache_logical = model.cache_specs()
    pspecs = tree_specs(cache_shapes, cache_logical, SERVE_RULES, mesh)
    # (L, B, S, kv, hd): batch over data, seq over model (kv=8 not div 16)
    assert pspecs["k"] == P(None, "data", "model")
