"""Tests for the K-S / reduction / CPD / outlier machinery (paper C3)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.stats import (
    boundary_suspect,
    cusum_change_point,
    detect_outliers,
    geometric_reduction,
    ks_2samp,
    ks_change_point,
    ks_critical_value,
    ks_pvalue,
    ks_statistic,
    pelt_segments,
    reduce_rows,
    winsorize,
)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- K-S test
class TestKS:
    def test_identical_samples_d_zero(self):
        a = np.arange(100.0)
        assert ks_statistic(a, a) == 0.0

    def test_disjoint_samples_d_one(self):
        a = np.zeros(50)
        b = np.ones(50)
        assert ks_statistic(a, b) == 1.0

    def test_known_value_small(self):
        # Hand-computed: a={1,2,3}, b={2,3,4}: max ECDF gap = 1/3 at x in [1,2).
        d = ks_statistic(np.array([1.0, 2, 3]), np.array([2.0, 3, 4]))
        assert math.isclose(d, 1.0 / 3.0, rel_tol=1e-12)

    def test_critical_value_formula(self):
        # eq. (1): alpha=0.05, n=m=100 -> sqrt(-0.5*(200/10000)*ln(0.025))
        expected = math.sqrt(-0.5 * (200 / 10000) * math.log(0.025))
        assert math.isclose(ks_critical_value(100, 100, 0.05), expected, rel_tol=1e-12)

    def test_critical_value_monotone_in_alpha(self):
        assert ks_critical_value(50, 50, 0.01) > ks_critical_value(50, 50, 0.10)

    def test_same_distribution_rarely_rejects(self):
        rejects = 0
        for i in range(50):
            rng = np.random.default_rng(i)
            a, b = rng.normal(size=200), rng.normal(size=200)
            rejects += ks_2samp(a, b, alpha=0.01).reject
        assert rejects <= 3  # ~alpha level

    def test_shifted_distribution_rejects(self):
        a = RNG.normal(0.0, 1.0, size=300)
        b = RNG.normal(2.5, 1.0, size=300)
        res = ks_2samp(a, b, alpha=0.01)
        assert res.reject and res.pvalue < 1e-6 and res.confidence > 0

    def test_pvalue_bounds(self):
        assert ks_pvalue(0.0, 10, 10) == 1.0
        assert ks_pvalue(1.0, 100, 100) < 1e-10

    @given(
        st.lists(st.floats(-1e6, 1e6), min_size=2, max_size=200),
        st.lists(st.floats(-1e6, 1e6), min_size=2, max_size=200),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_d_in_unit_interval_and_symmetric(self, xs, ys):
        a, b = np.array(xs), np.array(ys)
        d = ks_statistic(a, b)
        assert 0.0 <= d <= 1.0
        assert math.isclose(d, ks_statistic(b, a), abs_tol=1e-12)

    @given(st.lists(st.floats(-1e3, 1e3), min_size=5, max_size=100))
    @settings(max_examples=40, deadline=None)
    def test_property_self_test_never_rejects(self, xs):
        a = np.array(xs)
        assert not ks_2samp(a, a, alpha=0.001).reject


# ------------------------------------------------------------- reduction
class TestReduction:
    def test_matches_eq2(self):
        r = np.array([[1.0, 2.0], [3.0, 5.0]])
        gmin = 1.0
        expect0 = math.sqrt((1 - gmin) ** 2 + (2 - gmin) ** 2)
        expect1 = math.sqrt((3 - gmin) ** 2 + (5 - gmin) ** 2)
        out = geometric_reduction(r)
        assert np.allclose(out, [expect0, expect1])

    def test_constant_rows_reduce_to_scaled_offset(self):
        r = np.full((4, 16), 7.0)
        out = geometric_reduction(r)
        assert np.allclose(out, 0.0)  # min == all values

    def test_amplifies_regime_change(self):
        low = RNG.normal(10, 0.5, size=(8, 64))
        high = RNG.normal(100, 5.0, size=(8, 64))
        s = geometric_reduction(np.vstack([low, high]))
        assert s[8:].min() > s[:8].max() * 2

    def test_ragged_rows(self):
        rows = [np.array([1.0, 1.0]), np.array([5.0, 5.0, 5.0, 5.0])]
        out = reduce_rows(rows)
        assert out.shape == (2,) and out[1] > out[0]

    @given(st.integers(2, 20), st.integers(2, 40))
    @settings(max_examples=25, deadline=None)
    def test_property_nonnegative(self, nrow, ncol):
        rng = np.random.default_rng(nrow * 41 + ncol)
        out = geometric_reduction(rng.normal(size=(nrow, ncol)))
        assert np.all(out >= 0.0)


# ------------------------------------------------------------------- CPD
class TestKSChangePoint:
    def _step_series(self, n_left, n_right, lo, hi, noise, seed=0):
        rng = np.random.default_rng(seed)
        return np.concatenate([
            rng.normal(lo, noise, n_left),
            rng.normal(hi, noise, n_right),
        ])

    def test_clean_step_found_exactly(self):
        s = self._step_series(40, 40, 10.0, 100.0, 0.5)
        cp = ks_change_point(s, alpha=0.01)
        assert cp.found and abs(cp.index - 40) <= 1

    def test_no_change_not_found(self):
        s = RNG.normal(50.0, 1.0, size=80)
        cp = ks_change_point(s, alpha=0.001)
        assert not cp.found and cp.index == -1

    def test_outlier_robustness(self):
        # The paper's motivation for K-S: a lone spike must not become a CP.
        s = RNG.normal(50.0, 1.0, size=100)
        s[30] = 5000.0
        cp = ks_change_point(s, alpha=0.001)
        assert not cp.found

    def test_step_with_outliers_still_found(self):
        s = self._step_series(50, 50, 10.0, 100.0, 1.0, seed=3)
        s[10] = 900.0
        s[80] = 0.0
        cp = ks_change_point(s, alpha=0.01)
        assert cp.found and abs(cp.index - 50) <= 2

    def test_first_mode(self):
        s = self._step_series(30, 30, 0.0, 10.0, 0.1)
        cp = ks_change_point(s, alpha=0.01, mode="first")
        assert cp.found and cp.index <= 31

    @given(
        st.integers(10, 60), st.integers(10, 60),
        st.floats(1.0, 50.0), st.integers(0, 10_000),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_big_steps_always_found(self, nl, nr, noise, seed):
        rng = np.random.default_rng(seed)
        gap = noise * 50.0  # enormous separation
        s = np.concatenate([
            rng.normal(0.0, noise, nl), rng.normal(gap, noise, nr)])
        cp = ks_change_point(s, alpha=0.01)
        assert cp.found and abs(cp.index - nl) <= 3


class TestCUSUMAndPELT:
    def test_cusum_step(self):
        s = np.concatenate([np.full(50, 1.0), np.full(50, 9.0)])
        s += RNG.normal(0, 0.1, size=100)
        cp = cusum_change_point(s)
        assert cp.found and abs(cp.index - 50) <= 2

    def test_pelt_two_changes(self):
        rng = np.random.default_rng(7)
        s = np.concatenate([
            rng.normal(0, 0.3, 40), rng.normal(8, 0.3, 40), rng.normal(-4, 0.3, 40)])
        cps = pelt_segments(s)
        assert len(cps) == 2
        assert abs(cps[0] - 40) <= 2 and abs(cps[1] - 80) <= 2

    def test_pelt_no_change(self):
        s = RNG.normal(3.0, 0.5, size=100)
        assert pelt_segments(s) == []


class TestOutliers:
    def test_detect_spike(self):
        s = np.concatenate([RNG.normal(10, 0.5, 50), [500.0]])
        rep = detect_outliers(s)
        assert rep.any and 50 in rep.indices

    def test_boundary_suspect(self):
        s = RNG.normal(10, 0.5, 60)
        s[-1] = 999.0
        assert boundary_suspect(s)
        s2 = RNG.normal(10, 0.5, 60)
        s2[30] = 999.0
        assert not boundary_suspect(s2)

    def test_winsorize_clamps(self):
        s = np.concatenate([RNG.normal(0, 1, 98), [1e9, -1e9]])
        w = winsorize(s, pct=2.0)
        assert w.max() < 1e6 and w.min() > -1e6
