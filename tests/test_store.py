"""Topology-store tests: round-trip fidelity, read-through discovery that
issues ZERO runner probes on a hit, sample-cache persistence, corruption
recovery, and the catalog's discovered-before-datasheet fallback."""
import json
import os

import numpy as np
import pytest

from repro.core import (CATALOG, discover_sim, get_spec, make_h100_like,
                        make_mi210_like)
from repro.core.discover import sim_request_descriptor
from repro.core.engine import SampleCache
from repro.core.engine.store import TopologyStore, request_key

KIB = 1024

# Every runner method that reaches the device for measurement.
PROBE_METHODS = ("pchase", "pchase_batch", "cold_chase", "cold_chase_batch",
                 "amount_probe", "sharing_probe", "cu_sharing_probe",
                 "cu_sharing_probe_batch", "bandwidth")


class CountingDevice:
    """Transparent SimDevice proxy counting every probe-serving call."""

    def __init__(self, device):
        self._device = device
        self.probe_calls = 0

    def __getattr__(self, name):
        attr = getattr(self._device, name)
        if name in PROBE_METHODS:
            def counted(*args, _attr=attr, **kw):
                self.probe_calls += 1
                return _attr(*args, **kw)
            return counted
        return attr


@pytest.fixture
def store(tmp_path):
    return TopologyStore(str(tmp_path / "topo-store"))


class TestRequestKey:
    def test_deterministic_and_sensitive(self):
        d1 = sim_request_descriptor(make_h100_like(seed=1), 17, None)
        d2 = sim_request_descriptor(make_h100_like(seed=1), 17, None)
        assert request_key(d1) == request_key(d2)
        d3 = sim_request_descriptor(make_h100_like(seed=2), 17, None)
        d4 = sim_request_descriptor(make_h100_like(seed=1), 33, None)
        d5 = sim_request_descriptor(make_h100_like(seed=1), 17, ["L1"])
        keys = {request_key(d) for d in (d1, d3, d4, d5)}
        assert len(keys) == 4

    def test_key_order_insensitive(self):
        a = {"x": 1, "y": "z"}
        b = {"y": "z", "x": 1}
        assert request_key(a) == request_key(b)


class TestRoundTrip:
    def test_topology_disk_roundtrip_bit_equal(self, store):
        """Topology -> disk -> Topology, bit-equal including provenance,
        confidence (full precision), sharing lists, and notes."""
        topo, _ = discover_sim(make_h100_like(seed=21), n_samples=9)
        store.put("k1", topo)
        back = store.get("k1").topology
        assert back.to_json() == topo.to_json()
        l1a, l1b = topo.find_memory("L1"), back.find_memory("L1")
        assert l1b.attrs["size"].confidence == l1a.attrs["size"].confidence
        assert l1b.attrs["size"].provenance == l1a.attrs["size"].provenance
        assert l1b.shared_with == l1a.shared_with
        assert back.notes == topo.notes

    def test_meta_defaults_and_merge(self, store):
        topo, _ = discover_sim(make_h100_like(seed=21), n_samples=9)
        store.put("k1", topo, meta={"custom": "x"})
        meta = store.get("k1").meta
        assert meta["model"] == "sim-h100"
        assert meta["vendor"] == "NVIDIA"
        assert meta["custom"] == "x"
        assert meta["created_at"] > 0

    def test_sample_cache_roundtrip(self, store):
        cache = SampleCache()
        cache.get_or_run(("pchase", "L1", 1024, 32, 9),
                         lambda: np.arange(9.0))
        cache.get_or_run(("cold", "L1", 2048, 64, 9),
                         lambda: np.ones(9) * 3.5)
        store.put_samples("k1", cache.snapshot())
        loaded = store.load_samples("k1")
        assert set(loaded) == {("pchase", "L1", 1024, 32, 9),
                               ("cold", "L1", 2048, 64, 9)}
        assert np.array_equal(loaded[("pchase", "L1", 1024, 32, 9)],
                              np.arange(9.0))
        fresh = SampleCache()
        fresh.preload(loaded)
        hit = fresh.get_or_run(("cold", "L1", 2048, 64, 9),
                               lambda: (_ for _ in ()).throw(AssertionError))
        assert np.array_equal(hit, np.ones(9) * 3.5)


class TestReadThrough:
    def test_second_discovery_issues_zero_probes(self, store):
        """The acceptance headline: an identical request hits the store and
        never reaches the runner — asserted by counting device calls."""
        first = CountingDevice(make_h100_like(seed=31))
        topo1, _ = discover_sim(first, n_samples=9, store=store)
        assert first.probe_calls > 0

        second = CountingDevice(make_h100_like(seed=31))
        topo2, t2 = discover_sim(second, n_samples=9, store=store)
        assert second.probe_calls == 0
        assert topo2.to_json() == topo1.to_json()
        # the hit reconstructs the recorded per-family timings
        assert set(t2.per_family) >= {"size", "latency"}

    def test_different_request_misses(self, store):
        discover_sim(make_h100_like(seed=31), n_samples=9, store=store)
        other = CountingDevice(make_h100_like(seed=32))   # different seed
        discover_sim(other, n_samples=9, store=store)
        assert other.probe_calls > 0
        assert len(store.keys()) == 2

    def test_refresh_bypasses_read_but_writes_through(self, store):
        discover_sim(make_h100_like(seed=31), n_samples=9, store=store)
        dev = CountingDevice(make_h100_like(seed=31))
        topo, _ = discover_sim(dev, n_samples=9, store=store, refresh=True)
        assert dev.probe_calls > 0                    # re-measured
        key = store.keys()[0]
        assert store.get(key).topology.to_json() == topo.to_json()

    def test_refresh_ignores_stale_persisted_samples(self, store):
        """refresh=True is a real re-measure: tampered/stale sample rows on
        disk must not be preloaded into the probe cache."""
        topo, _ = discover_sim(make_h100_like(seed=34), n_samples=9,
                               store=store)
        key = store.keys()[0]
        stale = {k: np.asarray(v) * 7.0           # corrupt every latency row
                 for k, v in store.load_samples(key).items()}
        store.put_samples(key, stale)
        fresh, _ = discover_sim(make_h100_like(seed=34), n_samples=9,
                                store=store, refresh=True)
        # measured, not served stale (notes differ: they embed wall time)
        a, b = fresh.to_json(), topo.to_json()
        a.pop("notes"), b.pop("notes")
        assert a == b

    def test_legacy_path_also_writes_through(self, store):
        topo, _ = discover_sim(make_h100_like(seed=33), n_samples=9,
                               store=store, engine=False)
        dev = CountingDevice(make_h100_like(seed=33))
        topo2, _ = discover_sim(dev, n_samples=9, store=store)
        assert dev.probe_calls == 0
        assert topo2.to_json() == topo.to_json()


class TestCorruptionRecovery:
    def _key_and_path(self, store):
        key = store.keys()[0]
        return key, store._topo_path(key)

    def test_corrupt_topology_quarantined_and_rediscovered(self, store):
        discover_sim(make_h100_like(seed=41), n_samples=9, store=store)
        key, path = self._key_and_path(store)
        with open(path, "w") as f:
            f.write("{ not json !!")
        dev = CountingDevice(make_h100_like(seed=41))
        topo, _ = discover_sim(dev, n_samples=9, store=store)
        assert topo.find_memory("L1") is not None     # recovered via re-run
        assert not os.path.exists(path) or store.get(key) is not None
        assert store.corrupt >= 1
        assert os.listdir(os.path.join(store.root, "corrupt"))
        # the re-run wrote a fresh, readable entry back under the same key
        assert store.get(key).topology.to_json() == topo.to_json()

    def test_corrupt_samples_quarantined(self, store):
        discover_sim(make_h100_like(seed=41), n_samples=9, store=store)
        key = store.keys()[0]
        with open(store._samples_path(key), "wb") as f:
            f.write(b"\x00\x01 definitely not an npz")
        assert store.load_samples(key) is None
        assert store.corrupt >= 1

    def test_corrupt_topology_with_intact_samples_serves_from_cache(self, store):
        """Partial recovery: topology JSON lost, sample rows intact — the
        re-run reassembles from disk-served rows (only uncacheable calls
        like bandwidth reach the device)."""
        dev0 = CountingDevice(make_h100_like(seed=42))
        discover_sim(dev0, n_samples=9, store=store)
        full_run_calls = dev0.probe_calls
        key, path = self._key_and_path(store)
        os.remove(path)
        dev = CountingDevice(make_h100_like(seed=42))
        topo, _ = discover_sim(dev, n_samples=9, store=store)
        assert topo.find_memory("L1") is not None
        assert 0 < dev.probe_calls < full_run_calls / 2

    def test_missing_key_is_clean_miss(self, store):
        assert store.get("deadbeef" * 4) is None
        assert store.load_samples("deadbeef" * 4) is None
        assert store.stats()["misses"] >= 1


class TestCatalogFallback:
    def test_discovered_overrides_datasheet(self, store):
        topo, _ = discover_sim(make_h100_like(seed=51), n_samples=9,
                               store=store)
        # No static entry for the simulated device: served purely from store.
        spec = get_spec("sim-h100", store=store)
        dm = topo.find_memory("DeviceMemory")
        assert spec.hbm_bandwidth == pytest.approx(
            float(dm.get("read_bw")) * 1e9)
        assert spec.name == "sim-h100"
        assert "discovered" in spec.notes

    def test_static_answer_without_store(self):
        assert get_spec("tpu-v5e").hbm_bandwidth == CATALOG["tpu-v5e"].hbm_bandwidth
        with pytest.raises(KeyError, match="unknown hardware"):
            get_spec("sim-h100")

    def test_store_without_match_falls_back_to_datasheet(self, store):
        discover_sim(make_mi210_like(seed=51), n_samples=9, store=store)
        spec = get_spec("tpu-v5e", store=store)
        assert spec == CATALOG["tpu-v5e"]

    def test_newest_entry_wins(self, store):
        d1, _ = discover_sim(make_h100_like(seed=51), n_samples=9, store=store)
        # A later run of the same device identity under a different request:
        d2, _ = discover_sim(make_h100_like(seed=52), n_samples=9, store=store)
        entries = store.find(model="sim-h100")
        assert len(entries) == 2
        assert entries[0].meta["created_at"] >= entries[1].meta["created_at"]


class TestStoreHygiene:
    def test_atomic_write_leaves_no_tmp_files(self, store):
        discover_sim(make_h100_like(seed=61), n_samples=9, store=store)
        for sub in ("topologies", "samples"):
            names = os.listdir(os.path.join(store.root, sub))
            assert not [n for n in names if ".tmp." in n]

    def test_delete(self, store):
        discover_sim(make_h100_like(seed=61), n_samples=9, store=store)
        key = store.keys()[0]
        store.delete(key)
        assert not store.has(key)
        assert store.load_samples(key) is None

    def test_stored_doc_shape(self, store):
        """The on-disk document is plain JSON a non-Python consumer can read."""
        discover_sim(make_h100_like(seed=61), n_samples=9, store=store)
        key = store.keys()[0]
        with open(store._topo_path(key)) as f:
            doc = json.load(f)
        assert set(doc) == {"meta", "topology"}
        assert doc["meta"]["schema"] == 1
        assert doc["topology"]["vendor"] == "NVIDIA"


class TestStoreLocking:
    """Advisory write locking: one lock file per store root, re-entrant
    within a thread, exclusive across holders, and spanning the
    topology+samples persist pair so concurrent discoveries cannot
    interleave the two files of different runs."""

    def test_lock_file_created_and_reentrant(self, store):
        lock = store.lock()
        with lock:
            assert lock.held
            with lock:                     # re-entrant: no deadlock
                assert lock.held
            assert lock.held               # inner exit keeps the outer hold
        assert not lock.held
        assert os.path.exists(os.path.join(store.root, ".lock"))

    def test_exclusive_across_independent_holders(self, store):
        """A second StoreLock on the same path (another process's view)
        must block until the first releases."""
        import threading
        import time as _time

        from repro.core.engine.store import StoreLock

        other = StoreLock(os.path.join(store.root, ".lock"))
        order = []
        store.lock().acquire()
        try:
            t = threading.Thread(
                target=lambda: (other.acquire(), order.append("locked"),
                                other.release()))
            t.start()
            _time.sleep(0.15)
            assert order == []             # still blocked on our hold
        finally:
            store.lock().release()
        t.join(timeout=5)
        assert order == ["locked"]

    def test_writes_take_the_lock(self, store):
        """Bare put/put_samples/delete acquire the advisory lock on their
        own (observable through re-entrancy: they nest under a held lock
        without deadlocking, and leave it held afterwards)."""
        topo, _ = discover_sim(make_h100_like(seed=61), n_samples=9)
        lock = store.lock()
        with lock:
            store.put("lk", topo)
            store.put_samples("lk", {("pchase", "L1", 1, 2, 3):
                                     np.ones(3)})
            store.delete("lk")
            assert lock.held

    def test_in_process_thread_gate_mutual_exclusion(self, store):
        """ISSUE 6 satellite: two threads in one process must serialize on
        the store lock even where the file lock cannot arbitrate them
        (fcntl-emulated flock treats record locks as per-process).  The
        in-process ``threading.Lock`` layer makes the critical section
        single-occupancy by construction, observable as an occupancy
        counter that never exceeds 1."""
        import threading
        import time as _time

        lock = store.lock()
        inside = []
        overlaps = []

        def critical(tid):
            for _ in range(30):
                with lock:
                    inside.append(tid)
                    if len(inside) > 1:
                        overlaps.append(list(inside))
                    _time.sleep(0.0005)
                    inside.remove(tid)

        threads = [threading.Thread(target=critical, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not overlaps
        assert not lock.held

    def test_two_thread_persist_pair_never_interleaves(self, store):
        """Two threads persisting the topology+samples pair under the lock:
        the written pair must always come from a single writer (the
        event order is strictly enter/exit bracketed per thread)."""
        import threading

        topo, _ = discover_sim(make_h100_like(seed=73), n_samples=9)
        events = []

        def persist(writer_id):
            for i in range(10):
                with store.lock():
                    events.append(("enter", writer_id))
                    store.put(f"pair-{writer_id}", topo,
                              meta={"writer": writer_id, "i": i})
                    store.put_samples(f"pair-{writer_id}",
                                      {("w",): np.full(2, writer_id)})
                    events.append(("exit", writer_id))

        threads = [threading.Thread(target=persist, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        # strictly bracketed: every enter is immediately followed by the
        # same writer's exit — no interleaving inside the locked pair
        assert len(events) == 2 * 2 * 10
        for enter, exit_ in zip(events[::2], events[1::2]):
            assert enter == ("enter", exit_[1]) and exit_[0] == "exit"
        assert store.corrupt == 0

    def test_concurrent_persist_pairs_stay_consistent(self, store):
        """Writers racing on the SAME key must never interleave the
        topology/samples pair: whoever holds the lock last writes both
        files, so the final topology's marker and the final sample
        archive's marker must agree.  (Without the lock spanning the pair,
        the last topology and last samples can come from different
        writers.)"""
        import threading

        topo, _ = discover_sim(make_h100_like(seed=70), n_samples=9)

        def persist(writer_id):
            for _ in range(25):
                marked = json.loads(json.dumps(topo.to_json()))
                with store.lock():
                    store.put("contended", type(topo).from_json(marked),
                              meta={"writer": writer_id})
                    store.put_samples(
                        "contended",
                        {("writer",): np.full(3, writer_id, np.int64)})

        threads = [threading.Thread(target=persist, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        entry = store.get("contended")
        samples = store.load_samples("contended")
        assert entry is not None and samples is not None
        assert int(samples[("writer",)][0]) == entry.meta["writer"]
        assert store.corrupt == 0


class TestGenerations:
    """Per-key freshness tokens: the serving layer's staleness oracle."""

    def test_generation_changes_on_put_and_dies_on_delete(self, store):
        topo, _ = discover_sim(make_h100_like(seed=74), n_samples=9)
        assert store.generation("g") is None
        store.put("g", topo)
        g1 = store.generation("g")
        assert g1 is not None
        store.put("g", topo, meta={"rev": 2})
        g2 = store.generation("g")
        assert g2 is not None and g2 != g1
        store.delete("g")
        assert store.generation("g") is None

    def test_gc_eviction_kills_the_generation(self, store):
        topo, _ = discover_sim(make_h100_like(seed=75), n_samples=9)
        store.put("g", topo)
        assert store.generation("g") is not None
        store.gc(max_entries=0)
        assert store.generation("g") is None

    def test_quarantine_detection(self, store):
        topo, _ = discover_sim(make_h100_like(seed=76), n_samples=9)
        store.put("q", topo)
        assert not store.is_quarantined("q")
        with open(store._topo_path("q"), "w") as f:
            f.write("not json at all")
        assert store.get("q") is None            # quarantines the file
        assert store.is_quarantined("q")
        assert store.generation("q") is None
        # a fresh put clears the quarantined verdict (newer doc wins)
        store.put("q", topo)
        assert not store.is_quarantined("q")
        assert store.get("q") is not None

    def test_unknown_key_is_neither_present_nor_quarantined(self, store):
        assert store.generation("never-stored") is None
        assert not store.is_quarantined("never-stored")


class TestLockfileFallbackStaleBreak:
    """ISSUE 9 satellite: the non-fcntl lockfile fallback must break stale
    locks only when the recorded holder pid is verifiably dead — age alone
    never justifies unlinking another process's live lock, and a fresh
    lockfile is never touched regardless of its pid."""

    @pytest.fixture
    def fallback_lock(self, tmp_path, monkeypatch):
        """A StoreLock forced onto the exclusive-create lockfile path."""
        import repro.core.engine.store as store_module
        monkeypatch.setattr(store_module, "fcntl", None)
        return store_module.StoreLock(
            str(tmp_path / ".lock"), timeout=0.4, poll=0.01,
            stale_seconds=5.0)

    @staticmethod
    def _dead_pid():
        """A pid guaranteed to belong to no running process (reaped child)."""
        import subprocess
        import sys
        p = subprocess.Popen([sys.executable, "-c", "pass"])
        p.wait()
        return p.pid

    @staticmethod
    def _plant(path, content, *, age_s=0.0):
        with open(path, "w") as f:
            f.write(content)
        if age_s:
            import time as _time
            old = _time.time() - age_s
            os.utime(path, (old, old))

    def test_dead_holder_stale_lock_is_broken(self, fallback_lock):
        self._plant(fallback_lock.path, str(self._dead_pid()), age_s=1000.0)
        fallback_lock.acquire()                 # breaks + acquires, no timeout
        try:
            assert fallback_lock.held
            with open(fallback_lock.path) as f:
                assert int(f.read()) == os.getpid()
        finally:
            fallback_lock.release()
        assert not os.path.exists(fallback_lock.path)

    def test_live_holder_never_broken_regardless_of_age(self, fallback_lock):
        """The documented race fix: a decade-old lockfile whose holder is
        alive (a long critical section, not a crash) must never be broken."""
        self._plant(fallback_lock.path, str(os.getpid()), age_s=1_000_000.0)
        with pytest.raises(TimeoutError, match="store lock busy"):
            fallback_lock.acquire()
        assert os.path.exists(fallback_lock.path)   # lock left intact
        with open(fallback_lock.path) as f:
            assert int(f.read()) == os.getpid()

    def test_fresh_lock_not_broken_even_with_dead_pid(self, fallback_lock):
        """Age gates before liveness: a just-created lock (holder may not
        have written its pid yet, or pid was recycled) is left alone."""
        self._plant(fallback_lock.path, str(self._dead_pid()))
        with pytest.raises(TimeoutError, match="store lock busy"):
            fallback_lock.acquire()
        assert os.path.exists(fallback_lock.path)

    def test_unreadable_pid_is_treated_as_dead_once_stale(self, fallback_lock):
        self._plant(fallback_lock.path, "not-a-pid", age_s=1000.0)
        fallback_lock.acquire()
        fallback_lock.release()
        assert not os.path.exists(fallback_lock.path)

    def test_fallback_mutual_exclusion_and_release(self, fallback_lock):
        """Sanity: the fallback still excludes a second holder and the
        release unlinks so the next acquire is immediate."""
        import repro.core.engine.store as store_module
        other = store_module.StoreLock(fallback_lock.path, timeout=0.2,
                                       poll=0.01)
        fallback_lock.acquire()
        try:
            assert store_module.fcntl is None
            with pytest.raises(TimeoutError):
                other.acquire()
        finally:
            fallback_lock.release()
        other.acquire()                         # immediate after release
        other.release()


class TestCheckpointAPI:
    """ISSUE 9: checkpoint persistence for interrupted discoveries —
    put/load/clear round-trip, corruption quarantine, and lifecycle ties
    to delete/gc."""

    ENTRIES = {
        ("pchase", "L1", 4096, 32, 9): np.arange(9, dtype=np.float64),
        ("cold", "L2", 1 << 20, 64, 9): np.full(9, 3.5),
    }
    FAMILIES = [("L1", "size"), ("L1", "latency"), "<device>/sharing"]

    def test_roundtrip_bit_equal(self, store):
        assert not store.has_checkpoint("k1")
        store.put_checkpoint("k1", self.ENTRIES, self.FAMILIES)
        assert store.has_checkpoint("k1")
        entries, families = store.load_checkpoint("k1")
        assert set(entries) == set(self.ENTRIES)
        for k, arr in self.ENTRIES.items():
            np.testing.assert_array_equal(entries[k], arr)
        assert families == [("L1", "size"), ("L1", "latency"),
                            "<device>/sharing"]

    def test_missing_checkpoint_is_none(self, store):
        assert store.load_checkpoint("nope") is None
        assert not store.has_checkpoint("nope")

    def test_clear_checkpoint(self, store):
        store.put_checkpoint("k2", self.ENTRIES)
        store.clear_checkpoint("k2")
        assert not store.has_checkpoint("k2")
        store.clear_checkpoint("k2")            # idempotent on a missing file

    def test_corrupted_checkpoint_quarantined_to_miss(self, store):
        """A damaged checkpoint degrades to a from-scratch run — load
        returns None and the file is quarantined, never raised."""
        store.put_checkpoint("k3", self.ENTRIES)
        with open(store._ckpt_path("k3"), "wb") as f:
            f.write(b"\x00\x01 definitely not an npz")
        assert store.load_checkpoint("k3") is None
        assert not store.has_checkpoint("k3")   # quarantine moved it aside
        assert os.listdir(os.path.join(store.root, "corrupt"))

    def test_delete_removes_checkpoint(self, store):
        topo, _ = discover_sim(make_h100_like(seed=61), n_samples=9)
        store.put("k4", topo)
        store.put_checkpoint("k4", self.ENTRIES)
        store.delete("k4")
        assert store.get("k4") is None
        assert not store.has_checkpoint("k4")

    def test_gc_never_sweeps_checkpoints(self, store):
        """Checkpoints exist precisely for keys with no topology yet (an
        interrupted discovery awaiting resume); an aggressive gc must not
        treat them as orphans."""
        topo, _ = discover_sim(make_h100_like(seed=61), n_samples=9)
        store.put("old", topo)
        store.put_checkpoint("in-progress", self.ENTRIES)
        out = store.gc(max_entries=0)
        assert out["evicted"] == ["old"]
        assert store.has_checkpoint("in-progress")
        entries, _ = store.load_checkpoint("in-progress")
        assert set(entries) == set(self.ENTRIES)
