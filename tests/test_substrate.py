"""Train loop / optimizer / data / checkpoint / FT / serve / compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Train-loop/checkpoint/serve integration: many jit compiles.
# Deselected by `make test-fast`.
pytestmark = pytest.mark.slow

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import ByteCorpus, DataConfig, SyntheticLM
from repro.ft import (FailureInjector, RestartExhausted, StragglerDetector,
                      Supervisor)
from repro.models import get_model
from repro.serve import Engine, ServeConfig
from repro.train import (OptConfig, TrainConfig, compress_with_feedback,
                         dequantize, init_train_state, lr_at, make_train_step,
                         quantize, train_loop)


def _tiny_setup(microbatches=1, steps_total=64):
    cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
    model = get_model(cfg)
    tc = TrainConfig(
        opt=OptConfig(lr=3e-3, warmup_steps=4, total_steps=steps_total,
                      master_f32=True),
        microbatches=microbatches, ckpt_every=4)
    data = ByteCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                 global_batch=8))
    return cfg, model, tc, data


class TestOptimizer:
    def test_lr_schedule(self):
        oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
        assert float(lr_at(oc, jnp.int32(0))) == 0.0
        assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1.0, rel=1e-5)
        assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(0.1, rel=1e-4)

    def test_training_reduces_loss(self):
        cfg, model, tc, data = _tiny_setup()
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tc)
        state, hist = train_loop(model, tc, data, steps=30, state=state)
        first = np.mean([m["loss"] for _, m in hist[:3]])
        last = np.mean([m["loss"] for _, m in hist[-3:]])
        assert last < first * 0.8, (first, last)

    def test_grad_accum_equivalence(self):
        """microbatches=4 must match microbatches=1 numerically (f32)."""
        cfg, model, tc1, data = _tiny_setup(microbatches=1)
        tc4 = TrainConfig(opt=tc1.opt, microbatches=4)
        s1, _ = init_train_state(model, jax.random.PRNGKey(1), tc1)
        s4 = jax.tree.map(lambda x: x, s1)
        batch = data.batch_at(0)
        s1, m1 = jax.jit(make_train_step(model, tc1))(s1, batch)
        s4, m4 = jax.jit(make_train_step(model, tc4))(s4, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s4["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


class TestData:
    def test_determinism_and_restartability(self):
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=4))
        b1, b2 = d.batch_at(7), d.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d.batch_at(8)["tokens"], b1["tokens"])

    def test_host_sharding_disjoint(self):
        mk = lambda h: SyntheticLM(DataConfig(vocab_size=1000, seq_len=8,
                                              global_batch=8, n_hosts=2,
                                              host_id=h))
        a, b = mk(0).batch_at(3), mk(1).batch_at(3)
        assert a["tokens"].shape == (4, 8)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_targets_are_shifted_tokens(self):
        d = ByteCorpus(DataConfig(vocab_size=256, seq_len=16, global_batch=2))
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


class TestCheckpoint:
    def test_roundtrip_bitwise(self, tmp_path):
        cfg, model, tc, data = _tiny_setup()
        state, _ = init_train_state(model, jax.random.PRNGKey(2), tc)
        ck = Checkpointer(str(tmp_path))
        ck.save(5, state, extra={"note": "hi"})
        restored, extra = ck.restore(state, step=5)
        assert extra["note"] == "hi"
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.arange(10.0)}
        for s in (1, 2, 3, 4):
            ck.save_async(s, tree)
        ck.wait()
        assert ck.steps() == [3, 4]          # keep=2

    def test_resume_bitwise_equals_uninterrupted(self, tmp_path):
        """Checkpoint/restart at step 4 must reproduce the 8-step run exactly
        (deterministic pipeline + pure step)."""
        cfg, model, tc, data = _tiny_setup()
        s0, _ = init_train_state(model, jax.random.PRNGKey(3), tc)
        step_fn = jax.jit(make_train_step(model, tc))

        # Uninterrupted 8 steps.
        sa = jax.tree.map(lambda x: x, s0)
        sa, _ = train_loop(model, tc, data, steps=8, state=sa,
                           step_fn=step_fn)

        # 4 steps -> checkpoint -> restore -> 4 more.
        ck = Checkpointer(str(tmp_path))
        sb = jax.tree.map(lambda x: x, s0)
        sb, _ = train_loop(model, tc, data, steps=4, state=sb,
                           step_fn=step_fn)
        ck.save(4, sb)
        sb_restored, _ = ck.restore(sb, step=4)
        sb2, _ = train_loop(model, tc, data, steps=8, state=sb_restored,
                            start_step=4, step_fn=step_fn)
        for a, b in zip(jax.tree.leaves(sa["params"]),
                        jax.tree.leaves(sb2["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_supervisor_restarts_and_completes(self, tmp_path):
        cfg, model, tc, data = _tiny_setup()
        ck = Checkpointer(str(tmp_path))
        s0, _ = init_train_state(model, jax.random.PRNGKey(4), tc)
        step_fn = jax.jit(make_train_step(model, tc))
        injector = FailureInjector(fail_at={6})

        def train_fn(state, start):
            return train_loop(model, tc, data, steps=10, state=state,
                              start_step=start, checkpointer=ck,
                              step_fn=step_fn, callbacks=[injector])

        sup = Supervisor(ck, max_restarts=2)
        state, hist = sup.run(train_fn, s0)
        assert sup.restarts == 1
        assert any("restart from step" in l for l in sup.log)
        assert hist[-1][0] == 9              # completed all steps

    def test_supervisor_gives_up(self, tmp_path):
        ck = Checkpointer(str(tmp_path))

        def bad_fn(state, start):
            raise RuntimeError("always broken")

        sup = Supervisor(ck, max_restarts=2)
        with pytest.raises(RestartExhausted):
            sup.run(bad_fn, {"x": jnp.zeros(1)})

    def test_straggler_detector(self):
        det = StragglerDetector(threshold_sigmas=4.0)
        for i in range(20):
            assert not det.record(i, 1.0 + 0.01 * (i % 3))
        assert det.record(20, 5.0)           # 5x median -> flagged
        assert det.flagged and det.flagged[0][0] == 20


class TestCompression:
    def test_quantize_roundtrip_error_bound(self):
        x = np.random.default_rng(0).normal(size=(256,)).astype(np.float32)
        q, s = quantize(jnp.asarray(x), bits=8)
        err = np.abs(np.asarray(dequantize(q, s)) - x)
        assert err.max() <= float(s) * 0.5 + 1e-7

    def test_error_feedback_reduces_bias(self):
        """Accumulated error feedback keeps the long-run mean unbiased."""
        rng = np.random.default_rng(1)
        true_sum = np.zeros(64, np.float32)
        fed_sum = np.zeros(64, np.float32)
        err = jnp.zeros(64, jnp.float32)
        for _ in range(200):
            g = rng.normal(size=64).astype(np.float32) * 1e-3
            true_sum += g
            q, s, err = compress_with_feedback(jnp.asarray(g), err, bits=8)
            fed_sum += np.asarray(dequantize(q, s))
        resid = np.abs(fed_sum + np.asarray(err) - true_sum).max()
        assert resid < 1e-4

    def test_compressed_psum_single_device(self):
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
        g = jnp.linspace(-1, 1, 32)
        e = jnp.zeros(32)
        from repro.compat import shard_map
        fn = jax.jit(shard_map(
            lambda gg, ee: __import__("repro.train.grad_compress",
                                      fromlist=["compressed_psum"]
                                      ).compressed_psum(gg, ee, "d"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))
        out, err = fn(g, e)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=1e-2)


class TestServe:
    def test_greedy_generation_deterministic(self):
        cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(5))
        eng = Engine(model, params, ServeConfig(max_len=32, slots=2))
        prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
        a = eng.generate_batch(prompts, max_new=5)
        b = eng.generate_batch(prompts, max_new=5)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (2, 5)

    def test_generation_matches_stepwise_forward(self):
        """Engine output == greedy argmax of repeated full forwards."""
        cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(6))
        eng = Engine(model, params, ServeConfig(max_len=32))
        prompts = (np.arange(8, dtype=np.int32)[None] * 3) % cfg.vocab_size
        gen = eng.generate_batch(prompts, max_new=4)

        toks = prompts.copy()
        from repro.models import Runtime
        fwd = jax.jit(lambda p, b: model.forward(p, b, Runtime(q_chunk=0)))
        for i in range(4):
            logits, _ = fwd(params, {"tokens": jnp.asarray(toks, jnp.int32)})
            nxt = np.argmax(np.asarray(logits, np.float32)[:, -1], -1)
            assert nxt[0] == gen[0, i], f"mismatch at step {i}"
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)

    def test_continuous_batching_queue(self):
        cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
        model = get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(7))
        eng = Engine(model, params, ServeConfig(max_len=32, slots=2))
        reqs = [np.full(4, i, np.int32) for i in range(5)]
        outs = eng.serve(reqs, max_new=3)
        assert len(outs) == 5 and all(o.shape == (3,) for o in outs)
