"""Query-service tests: dotted-path lookups with aliases, batched lookup
grouping, the LRU hot set (generation-validated and thread-safe),
provenance/confidence filters, adjacency, and the topology diff endpoint."""
import threading

import pytest

from repro.core import discover_sim, make_h100_like, make_mi210_like
from repro.core.engine.store import TopologyStore
from repro.serve.topology_service import TopologyService

KIB, MIB = 1024, 1024**2


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    store = TopologyStore(str(tmp_path_factory.mktemp("svc") / "store"))
    discover_sim(make_h100_like(seed=71), n_samples=9, store=store)
    discover_sim(make_mi210_like(seed=72), n_samples=9, store=store)
    return store


@pytest.fixture
def svc(store):
    return TopologyService(store, hot_set=4)


def _key_of(store, model):
    return next(e.key for e in store.entries() if e.meta["model"] == model)


class TestQuery:
    def test_memory_attribute_lookup(self, svc, store):
        k = _key_of(store, "sim-h100")
        q = svc.query(k, "L1.size")
        assert q.found and q.element == "L1"
        assert abs(q.value - 238 * KIB) <= 2 * KIB
        assert q.unit == "B" and q.provenance == "benchmark"
        assert q.confidence > 0    # K-S confidence metric (unbounded above)

    def test_aliases(self, svc, store):
        k = _key_of(store, "sim-h100")
        assert svc.query(k, "hbm.bandwidth").element == "DeviceMemory"
        assert svc.query(k, "hbm.bandwidth").value == \
            svc.query(k, "DeviceMemory.read_bw").value
        assert svc.query(k, "l2.load_latency").found     # case-insensitive
        # l1 alias resolves vL1 on the AMD-style device
        k_amd = _key_of(store, "sim-mi210")
        assert svc.query(k_amd, "l1.size").element == "vL1"
        assert svc.query(k_amd, "vL1.latency").value == \
            svc.query(k_amd, "vL1.load_latency").value

    def test_general_and_compute_roots(self, svc, store):
        k = _key_of(store, "sim-h100")
        assert svc.query(k, "general.clock_domain").value == "cycles"
        assert svc.query(k, "compute.cores_per_sm").value == 128

    def test_misses_are_clean(self, svc, store):
        k = _key_of(store, "sim-h100")
        assert not svc.query(k, "L1.nonexistent").found
        assert not svc.query(k, "NoSuchElement.size").found
        assert not svc.query("0" * 32, "L1.size").found

    def test_batched_lookup_loads_each_topology_once(self, store):
        svc = TopologyService(store, hot_set=4)
        keys = store.keys()
        reqs = [(k, p) for k in keys
                for p in ("L2.load_latency", "hbm.bandwidth", "L1.size")] * 3
        store_reads_before = store.hits
        answers = svc.query_batch(reqs)
        assert len(answers) == len(reqs)
        assert all(a.found for a in answers)
        # one store read per distinct key, everything else from the hot set
        assert store.hits - store_reads_before == len(keys)
        # answers align with their requests
        for (k, p), a in zip(reqs, answers):
            assert (a.key, a.path) == (k, p)


class TestHotSet:
    def test_lru_eviction(self, store):
        svc = TopologyService(store, hot_set=1)
        k1, k2 = store.keys()
        svc.get(k1)
        svc.get(k2)          # evicts k1
        svc.get(k1)          # store read again
        stats = svc.stats()
        assert stats["hot_set"] == 1
        assert stats["lru_misses"] == 3

    def test_hot_hits_skip_the_store(self, store):
        svc = TopologyService(store, hot_set=4)
        k = store.keys()[0]
        svc.get(k)
        before = store.hits
        for _ in range(10):
            svc.get(k)
        assert store.hits == before
        assert svc.stats()["lru_hits"] == 10


class TestHotSetFreshness:
    """ISSUE 6 satellite: the LRU must never serve a dead generation —
    a refresh rewrite, a GC eviction, or a quarantine invalidates the
    cached object instead of pinning it forever."""

    def test_refresh_under_live_service_serves_the_new_value(self, tmp_path):
        store = TopologyStore(str(tmp_path / "fresh"))
        dev = make_h100_like(seed=91)
        discover_sim(dev, n_samples=9, store=store)
        key = store.keys()[0]

        svc = TopologyService(store, hot_set=4)
        stale = svc.query(key, "L1.load_latency")
        assert stale.found
        assert svc.query(key, "L1.load_latency").value == stale.value  # hot

        # refresh=True re-measures and rewrites the same content-addressed
        # key; same request => same values, but the service must reload.
        misses_before = svc.stats()["lru_misses"]
        discover_sim(dev, n_samples=9, store=store, refresh=True)
        assert svc.query(key, "L1.load_latency").value == stale.value
        assert svc.stats()["lru_misses"] > misses_before

        # a divergent rewrite (new driver/firmware run) is visible at once
        entry = store.get(key)
        entry.topology.find_memory("L1").set("load_latency", 777.5, "cyc",
                                             "benchmark")
        store.put(key, entry.topology, meta=entry.meta)
        assert svc.query(key, "L1.load_latency").value == 777.5

    def test_gc_eviction_stops_serving_the_cached_object(self, tmp_path):
        store = TopologyStore(str(tmp_path / "gcd"))
        discover_sim(make_h100_like(seed=92), n_samples=9, store=store)
        key = store.keys()[0]
        svc = TopologyService(store)
        assert svc.get(key) is not None           # hot
        store.gc(max_entries=0)
        assert svc.get(key) is None               # evicted, not stale-served

    def test_cross_process_writer_is_visible(self, tmp_path):
        """A second store handle on the same root (another process's view)
        rewriting a key invalidates this service's hot entry."""
        root = str(tmp_path / "shared")
        store = TopologyStore(root)
        discover_sim(make_h100_like(seed=93), n_samples=9, store=store)
        key = store.keys()[0]
        svc = TopologyService(store)
        svc.get(key)                              # hot

        other = TopologyStore(root)
        entry = other.get(key)
        entry.topology.find_memory("L1").set("size", 12345, "B", "benchmark")
        other.put(key, entry.topology, meta=entry.meta)
        assert svc.query(key, "L1.size").value == 12345


class TestThreadSafety:
    """ISSUE 6 satellite: LRU mutation and the hit/miss counters sit
    behind a lock — a threaded front end cannot corrupt them."""

    N_THREADS = 8
    QUERIES_PER_THREAD = 200

    def test_hammer_counters_sum_and_no_lost_entries(self, store):
        svc = TopologyService(store, hot_set=1)    # max eviction contention
        keys = store.keys()
        errors = []

        def hammer(tid):
            try:
                for i in range(self.QUERIES_PER_THREAD):
                    k = keys[(tid + i) % len(keys)]
                    assert svc.get(k) is not None
            except Exception as e:      # noqa: BLE001 — surfaced below
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        stats = svc.stats()
        # no lost counter increments: hits + misses == total get() calls
        assert stats["lru_hits"] + stats["lru_misses"] == \
            self.N_THREADS * self.QUERIES_PER_THREAD
        # the LRU respected its bound under contention
        assert stats["hot_set"] <= 1
        # and the store still serves every entry (nothing corrupted/lost)
        for k in keys:
            assert svc.query(k, "general.clock_domain").found

    def test_concurrent_query_batch_alignment(self, store):
        svc = TopologyService(store, hot_set=2)
        keys = store.keys()
        reqs = [(k, p) for k in keys
                for p in ("L2.load_latency", "hbm.bandwidth")] * 10
        bad = []

        def batch(_tid):
            for _ in range(20):
                answers = svc.query_batch(reqs)
                if not all(a.found and (a.key, a.path) == r
                           for a, r in zip(answers, reqs)):
                    bad.append(answers)

        threads = [threading.Thread(target=batch, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not bad


class TestFiltersAndAdjacency:
    def test_provenance_filter(self, svc, store):
        k = _key_of(store, "sim-h100")
        api = svc.attributes(k, provenance="api")
        bench = svc.attributes(k, provenance="benchmark")
        assert api and bench
        assert all(a.provenance == "api" for a in api)
        # L2 total size is API-provided (paper Table I), L1 size measured
        assert any(a.element == "L2" and a.path.endswith(".size") for a in api)

    def test_confidence_filter(self, svc, store):
        k = _key_of(store, "sim-h100")
        confident = svc.attributes(k, min_confidence=0.9)
        assert confident
        assert all(a.confidence >= 0.9 for a in confident)
        loose = svc.attributes(k, min_confidence=0.0)
        assert len(loose) >= len(confident)

    def test_adjacency_view(self, svc, store):
        k = _key_of(store, "sim-h100")
        adj = svc.adjacency(k)
        assert set(adj["L1"]) >= {"Texture", "Readonly"}
        assert "ConstL1" not in adj.get("L1", [])


class TestDiff:
    def test_same_device_same_seed_identical(self, store, tmp_path):
        other = TopologyStore(str(tmp_path / "other"))
        discover_sim(make_h100_like(seed=71), n_samples=9, store=other)
        # copy the second run into the main store under a distinct key
        entry = other.entries()[0]
        store.put("copy-under-test", entry.topology, meta=entry.meta)
        svc = TopologyService(store)
        d = svc.diff(_key_of(store, "sim-h100"), "copy-under-test")
        assert d.identical
        assert d.matching > 10
        store.delete("copy-under-test")

    def test_cross_vendor_diff_structured(self, svc, store):
        d = svc.diff(_key_of(store, "sim-h100"), _key_of(store, "sim-mi210"))
        assert not d.identical
        assert "L1" in d.only_in_a and "vL1" in d.only_in_b
        changed = {(c.element, c.attr) for c in d.changed}
        assert ("L2", "load_latency") in changed
        lat = next(c for c in d.changed
                   if (c.element, c.attr) == ("L2", "load_latency"))
        assert lat.rel_delta > 0.2

    def test_rel_tol_absorbs_jitter(self, svc, store, tmp_path):
        other = TopologyStore(str(tmp_path / "jitter"))
        discover_sim(make_h100_like(seed=99), n_samples=9, store=other)
        entry = other.entries()[0]
        store.put("jitter-run", entry.topology, meta=entry.meta)
        svc2 = TopologyService(store)
        strict = svc2.diff(_key_of(store, "sim-h100"), "jitter-run")
        loose = svc2.diff(_key_of(store, "sim-h100"), "jitter-run",
                          rel_tol=0.25)
        assert len(loose.changed) <= len(strict.changed)
        assert loose.matching >= strict.matching
        store.delete("jitter-run")

    def test_missing_key_raises(self, svc, store):
        with pytest.raises(KeyError, match="not in store"):
            svc.diff(store.keys()[0], "nope")
